//! AMP study (paper §IV-C, Figs 4, 6, 8, 9): profile the DeepCAM
//! backward pass under every mixed-precision policy and report runtime,
//! tensor-core utilization and cast overhead per policy — including the
//! manual-FP16 ≈ AMP equivalence that Fig. 8 demonstrates.
//!
//! Run: `cargo run --release --example amp_study`

use hroofline::device::GpuSpec;
use hroofline::dl::deepcam::{deepcam, DeepCamConfig};
use hroofline::dl::lower::{lower, Framework, Phase};
use hroofline::dl::Policy;
use hroofline::profiler::{ProfileRequest, Session};
use hroofline::util::error as anyhow;
use hroofline::util::{fmt, Table};

fn main() -> anyhow::Result<()> {
    let spec = GpuSpec::v100();
    let graph = deepcam(&DeepCamConfig::paper());

    println!("AMP policy study — DeepCAM backward pass on the simulated V100\n");
    for fw in [Framework::TensorFlow, Framework::PyTorch] {
        let mut table = Table::new(&[
            "policy",
            "bwd time",
            "speedup vs O0",
            "TC time share",
            "cast launches",
        ]);
        let mut t_o0 = None;
        for policy in [Policy::O0, Policy::O1, Policy::O2, Policy::ManualFp16] {
            let trace = lower(&graph, fw, policy, &spec);
            let profile =
                Session::standard(&spec).run(&ProfileRequest::new(trace.phase(Phase::Backward)))?;
            let total = profile.total_seconds();
            if policy == Policy::O0 {
                t_o0 = Some(total);
            }
            let tc_time: f64 = profile
                .by_time()
                .iter()
                .filter(|k| k.is_tensor_dominated())
                .map(|k| k.seconds())
                .sum();
            let casts: u64 = trace
                .phase(Phase::Backward)
                .iter()
                .chain(trace.phase(Phase::Forward))
                .filter(|i| i.kernel.name.contains("cast") || i.kernel.name.contains("autocast"))
                .map(|i| i.invocations)
                .sum();
            table.row(&[
                policy.name().to_string(),
                fmt::duration(total),
                format!("{:.2}x", t_o0.unwrap() / total),
                fmt::pct(if total > 0.0 { tc_time / total } else { 0.0 }),
                casts.to_string(),
            ]);
        }
        println!("== {} ==\n{}", fw.name(), table.render());
    }

    // The Fig. 8 equivalence, quantified.
    let amp_trace = lower(&graph, Framework::TensorFlow, Policy::O1, &spec);
    let tf_amp = Session::standard(&spec)
        .run(&ProfileRequest::new(amp_trace.phase(Phase::Backward)))?
        .total_seconds();
    let manual_trace = lower(&graph, Framework::TensorFlow, Policy::ManualFp16, &spec);
    let tf_manual = Session::standard(&spec)
        .run(&ProfileRequest::new(manual_trace.phase(Phase::Backward)))?
        .total_seconds();
    println!(
        "Fig. 8 check: TF manual-FP16 backward {} vs AMP backward {} ({:+.2}%)",
        fmt::duration(tf_manual),
        fmt::duration(tf_amp),
        (tf_manual / tf_amp - 1.0) * 100.0
    );
    Ok(())
}
