//! Bench: regenerate Fig. 2 (tensor-core GEMM vs matrix size), and —
//! when artifacts are present — anchor the small sizes with *real*
//! Pallas GEMM executions through PJRT.

use hroofline::bench_harness::{black_box, Bench};
use hroofline::device::GpuSpec;
use hroofline::ert::gemm::gemm_sweep;
use hroofline::runtime::engine::literal_f32;
use hroofline::runtime::{ArtifactStore, Engine};

fn main() {
    let artifact = hroofline::report::fig2::generate().expect("fig2");
    println!("{}", artifact.text);
    let _ = artifact.write_all(std::path::Path::new("out/report"));

    let mut b = Bench::new("fig2_gemm_sweep");
    b.case("modeled_sweep", || {
        let spec = GpuSpec::v100();
        black_box(gemm_sweep(&spec).len() as u64)
    });
    b.run();

    // Real small-GEMM anchor: execute the Pallas gemm artifact and report
    // attained host FLOP/s (documents that the same harness runs real
    // kernels; absolute numbers are host-CPU-scale).
    match ArtifactStore::open_default().and_then(|store| {
        let engine = Engine::cpu()?;
        let module = engine.load(&store, "gemm_256")?;
        let n = 256usize;
        let x = literal_f32(&vec![1.0f32; n * n], &[n, n])?;
        let w = literal_f32(&vec![0.5f32; n * n], &[n, n])?;
        engine.run_timed(&module, &[x, w], 2, 10)
    }) {
        Ok(timed) => {
            let flops = 2.0 * 256f64.powi(3);
            println!(
                "real pallas gemm_256 via PJRT: median {:.3} ms -> {}",
                timed.secs.median * 1e3,
                hroofline::util::fmt::si_flops(flops / timed.secs.median)
            );
        }
        Err(e) => println!("(skipping real-GEMM anchor: {e:#})"),
    }
}
