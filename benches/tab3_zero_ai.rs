//! Bench: regenerate Table III (zero-AI invocation census) and time the
//! census path (lowering both frameworks + counting).

use hroofline::bench_harness::{black_box, Bench};
use hroofline::dl::lower::Framework;
use hroofline::report::tab3;

fn main() {
    let artifact = tab3::generate().expect("tab3");
    println!("{}", artifact.text);
    let _ = artifact.write_all(std::path::Path::new("out/report"));

    let mut b = Bench::new("tab3_zero_ai").iters(10);
    b.case("census", || {
        let c = tab3::census();
        black_box(
            c.total_zero_ai(Framework::TensorFlow) + c.total_zero_ai(Framework::PyTorch),
        )
    });
    b.run();
}
