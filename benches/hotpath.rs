//! Hot-path micro-benches for the §Perf optimization pass (L3 targets):
//!
//! * the analytic cache/cycle simulator (per-kernel cost, with and
//!   without the `SimCache` memoizer),
//! * profiler session throughput (kernels/second through a standard
//!   metric collection) — `profile_full_step` is the headline number;
//!   `profile_full_step_unmemoized` is the ablation against the
//!   pre-memoization behaviour,
//! * SVG chart emission,
//! * the exact set-associative cache simulator (ablation: exact vs
//!   analytic) — `cache_exact_100k_accesses` is the other headline;
//!   `cache_sim_soa_stream` tracks the SoA tag-scan on a hit-heavy
//!   stream,
//! * streaming CSV ingest throughput (`ingest_100k_rows`: the
//!   `repro ingest` chunked-parse + dedup-fold hot loop),
//! * PJRT train-step execution (when artifacts are present) — the only
//!   real-hardware hot path.
//!
//! Every run writes `BENCH_hotpath.json` (case → ns/iter + items/sec);
//! CI archives it so the perf trajectory is diffable across PRs.

use hroofline::bench_harness::{black_box, Bench};
use hroofline::device::{GpuSpec, Precision};
use hroofline::dl::deepcam::{deepcam, DeepCamConfig};
use hroofline::dl::lower::{lower, Framework, Phase};
use hroofline::dl::Policy;
use hroofline::profiler::{ProfileRequest, Session, SessionConfig};
use hroofline::roofline::chart::RooflineChart;
use hroofline::roofline::model::RooflineModel;
use hroofline::sim::{self, cache_sim, KernelDesc, SimCache};

fn main() {
    let spec = GpuSpec::v100();
    let graph = deepcam(&DeepCamConfig::paper());
    let trace = lower(&graph, Framework::PyTorch, Policy::O1, &spec);
    let all = trace.all();
    let n_inv: u64 = all.iter().map(|i| i.invocations).sum();

    let mut b = Bench::new("hotpath");

    // single-kernel simulation cost
    let k = KernelDesc::gemm("bench", 2048, 2048, 2048, Precision::Fp16, true, 128, &spec);
    b.case("simulate_one_kernel", move || {
        let spec = GpuSpec::v100();
        let c = sim::simulate(&spec, &k);
        black_box(c.elapsed_seconds());
        1
    });

    // framework lowering
    {
        let graph = graph.clone();
        b.case("lower_pytorch_paper", move || {
            let spec = GpuSpec::v100();
            let t = lower(&graph, Framework::PyTorch, Policy::O1, &spec);
            black_box(t.all().len() as u64)
        });
    }

    // memoized re-simulation of the full trace (K distinct kernels)
    {
        let all = all.clone();
        b.case("simulate_trace_memoized", move || {
            let spec = GpuSpec::v100();
            let mut cache = SimCache::new(&spec);
            let mut acc = 0.0f64;
            for inv in &all {
                acc += cache.simulate(&inv.kernel).elapsed_seconds();
            }
            black_box(acc);
            all.len() as u64
        });
    }

    // full profiling session over the whole training step (headline).
    // Counters-only keeps this case comparable with its pre-timing
    // baseline; `profile_step_timed` below tracks the timed default.
    {
        let all = all.clone();
        b.case("profile_full_step", move || {
            let spec = GpuSpec::v100();
            let p = Session::standard(&spec)
                .run(&ProfileRequest::new(&all).counters_only())
                .unwrap();
            black_box(p.n_kernels() as u64);
            n_inv
        });
    }

    // the timed default path: counters + per-kernel cycle breakdowns
    // (the time-based Roofline input)
    {
        let all = all.clone();
        b.case("profile_step_timed", move || {
            let spec = GpuSpec::v100();
            let p = Session::standard(&spec).run(&ProfileRequest::new(&all)).unwrap();
            black_box(p.n_kernels() as u64);
            n_inv
        });
    }

    // the timed path with span tracing + metrics armed: the delta to
    // profile_step_timed is the whole observability overhead (span
    // allocation, clock reads, counter increments) — the layer's
    // "strictly cheap" claim, kept honest by the trajectory gate
    {
        let all = all.clone();
        b.case("profile_step_traced", move || {
            let spec = GpuSpec::v100();
            let tracer = hroofline::obs::Tracer::new();
            let metrics = hroofline::obs::MetricsRegistry::new();
            let n = {
                let root = tracer.span("bench");
                let p = Session::standard(&spec)
                    .run(&ProfileRequest::new(&all).with_span(&root).with_metrics(&metrics))
                    .unwrap();
                p.n_kernels() as u64
            };
            black_box(n);
            black_box(tracer.records().len() as u64);
            n_inv
        });
    }

    // ablation: the same session with memoization off and a single
    // worker — the pre-optimization per-entry behaviour
    {
        let all = all.clone();
        b.case("profile_full_step_unmemoized", move || {
            let spec = GpuSpec::v100();
            let cfg = SessionConfig { memoize: false, threads: Some(1), ..Default::default() };
            let p = Session::new(&spec, cfg)
                .run(&ProfileRequest::new(&all).counters_only())
                .unwrap();
            black_box(p.n_kernels() as u64);
            n_inv
        });
    }

    // the scenario-matrix sweep in CI smoke configuration (restricted
    // workload set): graph builds + lowerings + shared-cache profiling
    b.case("matrix_quick_sweep", || {
        let matrix = hroofline::scenario::ScenarioMatrix::quick()
            .with_workloads("deepcam-lite,transformer")
            .expect("registered workloads");
        let run = matrix.run();
        black_box(run.sim_stats.1);
        run.results.len() as u64
    });

    // the same sweep served entirely from a pre-warmed cell store: the
    // `--incremental` CI path (graph builds + lowering + key hashing +
    // store decode, zero simulations). The gap to matrix_quick_sweep is
    // what incrementality buys per warm cell.
    let incr_dir =
        std::env::temp_dir().join(format!("hroofline-bench-incr-{}", std::process::id()));
    {
        let _ = std::fs::remove_dir_all(&incr_dir);
        let store = hroofline::scenario::store::CellStore::open(&incr_dir).expect("store dir");
        let smoke_matrix = || {
            hroofline::scenario::ScenarioMatrix::quick()
                .with_workloads("deepcam-lite,transformer")
                .expect("registered workloads")
        };
        // Pre-warm outside the timed loop.
        let warm_opts = hroofline::scenario::MatrixRunOptions {
            store: Some(&store),
            incremental: true,
            ..Default::default()
        };
        let cold = smoke_matrix().run_with(&warm_opts);
        assert_eq!(cold.cache_stats.hits, 0, "pre-warm run starts cold");
        b.case("matrix_quick_incremental_warm", move || {
            let options = hroofline::scenario::MatrixRunOptions {
                store: Some(&store),
                incremental: true,
                ..Default::default()
            };
            let run = smoke_matrix().run_with(&options);
            assert_eq!(run.sim_stats.1, 0, "warm run must simulate nothing");
            black_box(run.cache_stats.hits);
            run.results.len() as u64
        });
    }

    // cell-store round-trip cost: 1k save + load pairs of a small
    // profile (JSON encode, tmp+rename publish, strict decode)
    let store_dir =
        std::env::temp_dir().join(format!("hroofline-bench-store-{}", std::process::id()));
    {
        let _ = std::fs::remove_dir_all(&store_dir);
        let store = hroofline::scenario::store::CellStore::open(&store_dir).expect("store dir");
        let spec2 = GpuSpec::v100();
        let small_trace = vec![hroofline::sim::kernel::KernelInvocation::once(
            KernelDesc::streaming_elementwise("store-bench", 1 << 14, Precision::Fp32, 1),
        )];
        let profile =
            Session::standard(&spec2).run(&ProfileRequest::new(&small_trace)).unwrap();
        b.case("cell_store_roundtrip_1k", move || {
            let mut acc = 0usize;
            for i in 0..1000u32 {
                let key = hroofline::scenario::store::CellKey::new(format!("{i:032x}"));
                store.save(&key, "bench", &profile).unwrap();
                match store.load(&key) {
                    hroofline::scenario::store::Lookup::Hit(p) => acc += p.n_kernels(),
                    other => panic!("expected a hit, got {other:?}"),
                }
            }
            black_box(acc as u64);
            1000
        });
    }

    // one DeepCAM training step per registered device (quick scale so
    // the bench stays CI-sized): BENCH_hotpath.json tracks the
    // simulator's per-device cost as the registry grows
    {
        let quick_graph = hroofline::dl::workloads::lookup("deepcam-paper")
            .expect("registered workload")
            .build(hroofline::dl::Scale::Quick);
        for entry in hroofline::device::registry::entries() {
            let graph = quick_graph.clone();
            b.case(&format!("profile_step_quick_{}", entry.short), move || {
                let spec = entry.spec();
                let trace = lower(&graph, Framework::PyTorch, Policy::O1, &spec);
                let all = trace.all();
                let p = Session::standard(&spec)
                    .run(&ProfileRequest::new(&all).counters_only())
                    .unwrap();
                black_box(p.n_kernels() as u64);
                all.iter().map(|i| i.invocations).sum()
            });
        }
    }

    // roofline + SVG emission
    {
        let spec2 = GpuSpec::v100();
        let profile = Session::standard(&spec2)
            .run(&ProfileRequest::new(trace.phase(Phase::Backward)))
            .unwrap();
        b.case("chart_svg_emit", move || {
            let spec = GpuSpec::v100();
            let model = RooflineModel::from_profile(&spec, &profile);
            let chart = RooflineChart::hierarchical(&model, "bench");
            black_box(chart.to_svg().len() as u64)
        });
    }

    // ablation: exact set-associative simulation vs the analytic model
    b.case("cache_exact_100k_accesses", || {
        let mut h = cache_sim::v100_scaled(64);
        let mut rng = hroofline::util::Rng::new(1);
        for _ in 0..100_000 {
            h.access(rng.below(1 << 24));
        }
        black_box(h.mem_bytes);
        100_000
    });
    b.case("cache_analytic_100k_kernels", || {
        let spec = GpuSpec::v100();
        let cm = sim::CacheModel::new(&spec);
        let k = KernelDesc::streaming_elementwise("x", 1 << 16, Precision::Fp32, 2);
        let mut acc = 0u64;
        for _ in 0..100_000 {
            acc = acc.wrapping_add(cm.traffic(&k).hbm_bytes);
        }
        black_box(acc);
        100_000
    });
    // the SoA tag-scan hot loop under a high-hit-rate looping stream —
    // the best case for the contiguous tag array (every access walks
    // the set's tags; most return on the hit path without touching the
    // victim bookkeeping)
    b.case("cache_sim_soa_stream", || {
        let mut h = cache_sim::v100_scaled(64);
        for i in 0..100_000u64 {
            // Small-loop reuse with a strided escape every 16th access:
            // mostly L1 hits, enough misses to exercise eviction.
            let addr = if i % 16 == 0 { i * 128 } else { (i % 64) * 128 };
            h.access(addr);
        }
        black_box(h.l1.hits);
        100_000
    });

    // streaming CSV ingest throughput: 100k (kernel, metric) rows — the
    // `repro ingest` hot loop (chunked line re-assembly + row parse +
    // digest-keyed fold), CSV text built outside the timed region
    {
        let metric_names = [
            "sm__cycles_elapsed.avg",
            "dram__bytes.sum",
            "lts__t_bytes.sum",
            "l1tex__t_bytes.sum",
        ];
        let mut csv = String::with_capacity(100_000 * 48);
        csv.push_str("\"Kernel Name\",\"Metric Name\",\"Metric Value\",\"Invocations\"\n");
        for _ in 0..100u32 {
            for k in 0..250u32 {
                for m in &metric_names {
                    csv.push_str(&format!("\"kern_{k}\",\"{m}\",{},{}\n", k + 1, 1 + k % 5));
                }
            }
        }
        b.case("ingest_100k_rows", move || {
            let spec = GpuSpec::v100();
            let out = hroofline::profiler::ingest::from_reader(
                &mut csv.as_bytes(),
                &spec,
                &hroofline::profiler::IngestConfig::new(),
            )
            .unwrap();
            assert_eq!(out.stats.unique_kernels, 250);
            black_box(out.stats.rows);
            100_000
        });
    }

    // supervision overhead ablation: the panic-safe fan-out vs the raw
    // one over 10k trivially cheap items — the worst case for per-item
    // bookkeeping (catch_unwind, slot mutexes, attempt accounting)
    b.case("exec_parallel_map_raw_10k", || {
        let items: Vec<u64> = (0..10_000).collect();
        let out = hroofline::exec::parallel_map(items, 4, |x| x.wrapping_mul(0x9e37_79b9));
        black_box(out.len() as u64);
        10_000
    });
    b.case("exec_parallel_try_map_supervised_10k", || {
        let items: Vec<u64> = (0..10_000).collect();
        let policy = hroofline::exec::SupervisePolicy::default();
        let out = hroofline::exec::parallel_try_map(items, 4, &policy, |x| {
            Ok(x.wrapping_mul(0x9e37_79b9))
        });
        black_box(out.iter().filter(|r| r.is_ok()).count() as u64);
        10_000
    });

    b.run();
    let _ = std::fs::remove_dir_all(&incr_dir);
    let _ = std::fs::remove_dir_all(&store_dir);

    // Real PJRT hot path (separate group; skipped without artifacts).
    if let Ok(store) = hroofline::runtime::ArtifactStore::open_default() {
        let engine = hroofline::runtime::Engine::cpu().expect("cpu client");
        if let Ok(module) = engine.load(&store, "gemm_128") {
            let x = hroofline::runtime::engine::literal_f32(&vec![1.0; 128 * 128], &[128, 128])
                .unwrap();
            let w = hroofline::runtime::engine::literal_f32(&vec![0.5; 128 * 128], &[128, 128])
                .unwrap();
            let mut b2 = Bench::new("hotpath_pjrt").iters(20);
            b2.case("gemm128_execute", move || {
                let out = engine.run(&module, &[x.clone(), w.clone()]).unwrap();
                black_box(out.len() as u64)
            });
            b2.run();
        }
    } else {
        println!("(hotpath_pjrt skipped: run `make artifacts`)");
    }
}
