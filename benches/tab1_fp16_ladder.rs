//! Bench: regenerate Table I (the FP16 CUDA-core tuning ladder) and
//! validate each rung against the paper's measurement.

use hroofline::bench_harness::{black_box, Bench};
use hroofline::device::GpuSpec;
use hroofline::ert::fp16_ladder::ladder;

fn main() {
    let artifact = hroofline::report::tab1::generate().expect("tab1");
    println!("{}", artifact.text);
    let _ = artifact.write_all(std::path::Path::new("out/report"));

    let mut b = Bench::new("tab1_fp16_ladder");
    b.case("ladder_eval", || {
        let spec = GpuSpec::v100();
        let total: f64 = ladder().iter().map(|v| v.tflops(&spec)).sum();
        black_box(total as u64)
    });
    b.run();
}
