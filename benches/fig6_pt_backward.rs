//! Bench: regenerate fig6 (hierarchical Roofline of DeepCAM) and time
//! the full analysis pipeline (lower -> profile -> roofline -> SVG).

use hroofline::bench_harness::{black_box, Bench};

fn main() {
    let artifact = hroofline::report::generate("fig6").expect("fig6");
    println!("{}", artifact.text);
    let _ = artifact.write_all(std::path::Path::new("out/report"));

    let mut b = Bench::new("fig6_pt_backward").iters(10);
    b.case("generate", || {
        let a = hroofline::report::generate("fig6").unwrap();
        black_box(a.svg.map(|s| s.len()).unwrap_or(0) as u64)
    });
    b.run();
}
