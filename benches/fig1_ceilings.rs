//! Bench: regenerate Fig. 1 (ERT machine characterization) and measure
//! the sweep cost. Prints paper-vs-ours ceiling rows.

use hroofline::bench_harness::{black_box, Bench};
use hroofline::device::GpuSpec;
use hroofline::ert::modeled;
use hroofline::ert::sweep::SweepConfig;

fn main() {
    // Correctness/shape first: print the reproduction table.
    let artifact = hroofline::report::fig1::generate().expect("fig1");
    println!("{}", artifact.text);
    let _ = artifact.write_all(std::path::Path::new("out/report"));

    // Then the harness cost (modeled sweep is a hot analysis path).
    let mut b = Bench::new("fig1_ceilings");
    b.case("modeled_sweep_quick", || {
        let spec = GpuSpec::v100();
        let c = modeled::characterize(&spec, &SweepConfig::quick());
        black_box(c.compute_gflops.len() as u64)
    });
    b.case("modeled_sweep_standard", || {
        let spec = GpuSpec::v100();
        let c = modeled::characterize(&spec, &SweepConfig::standard());
        black_box(c.compute_gflops.len() as u64)
    });
    b.run();
}
